"""repro.dag: multi-stage DAG jobs, fused stage-composed rollouts, joint
per-stage search, and the stage-aware event engine.

Anchors:
  * the degenerate one-stage DAG reproduces the single-stage fleet engines
    on the SAME key — bit-level vs `fleet.vector.frontier` (shared draw
    structure) and to float tolerance vs `fleet_rollout` (baseline);
  * the two-stage fused rollout agrees with the stage-aware event engine
    (`DagFleetSim`, aligned per-stage pools) within Monte-Carlo error;
  * barrier monotonicity: adding a stage can never reduce a job's sojourn,
    checked pathwise inside one rollout;
  * critical-path shares sum to 1 exactly on both engines;
  * the Pallas kw_queue kernel path ≡ the scan path at 1e-5.
"""

import jax
import numpy as np
import pytest

from repro.core import ShiftedExp, SingleForkPolicy
from repro.dag import (
    DagFleetConfig,
    DagFleetSim,
    JobDAG,
    StageSpec,
    coordinate_search,
    dag_frontier,
    dag_rollout,
    exhaustive_search,
    poisson_arrivals,
    uniform_vectors,
)
from repro.fleet import vector

BASE = SingleForkPolicy(0.0, 0, True)
KEEP = SingleForkPolicy(0.2, 1, True)
KILL = SingleForkPolicy(0.25, 1, False)
MAP_DIST = ShiftedExp(1.0, 1.0)
RED_DIST = ShiftedExp(0.5, 2.0)


def two_stage(map_policy=KEEP, reduce_policy=BASE, c_map=2, c_reduce=2):
    return JobDAG.map_reduce(
        8, 4, MAP_DIST, RED_DIST, map_policy=map_policy,
        reduce_policy=reduce_policy, c_map=c_map, c_reduce=c_reduce,
    )


# ----------------------------------------------------------------- graph


def test_graph_validation():
    with pytest.raises(ValueError, match="topological"):
        JobDAG([
            StageSpec("a", 4, MAP_DIST, deps=("b",)),
            StageSpec("b", 4, MAP_DIST),
        ])
    with pytest.raises(ValueError, match="unknown stage"):
        JobDAG([StageSpec("a", 4, MAP_DIST, deps=("ghost",))])
    with pytest.raises(ValueError, match="duplicate"):
        JobDAG([StageSpec("a", 4, MAP_DIST), StageSpec("a", 4, MAP_DIST)])
    with pytest.raises(ValueError, match="n_tasks"):
        StageSpec("a", 0, MAP_DIST)
    with pytest.raises(ValueError, match="at least one stage"):
        JobDAG([])
    # a stage cannot name itself as a dependency (no earlier occurrence)
    with pytest.raises(ValueError, match="topological"):
        JobDAG([StageSpec("a", 4, MAP_DIST, deps=("a",))])


def test_graph_views_and_builders():
    dag = JobDAG([
        StageSpec("m1", 4, MAP_DIST),
        StageSpec("m2", 4, MAP_DIST),
        StageSpec("r", 2, RED_DIST, deps=("m1", "m2")),
    ])
    assert dag.sources == ("m1", "m2")
    assert dag.sinks == ("r",)
    assert dag.succs["m1"] == ("r",)
    pipe = JobDAG.pipeline([
        StageSpec("a", 4, MAP_DIST),
        StageSpec("b", 4, MAP_DIST),
        StageSpec("c", 4, MAP_DIST),
    ])
    assert pipe.preds == {"a": (), "b": ("a",), "c": ("b",)}
    # raw trace slices wrap into Empirical
    s = StageSpec("t", 4, np.array([1.0, 2.0, 3.0]))
    from repro.core import Empirical

    assert isinstance(s.dist, Empirical)
    with pytest.raises(ValueError, match="policy vector"):
        pipe.validate_policy_vector((BASE,))


# ------------------------------------------- degenerate one-stage anchors


def test_one_stage_equals_frontier_exact_crn():
    """Same key, same draw structure: a one-stage DAG cell is the fused
    single-stage frontier cell, draw for draw."""
    one = JobDAG([StageSpec("s", 8, MAP_DIST, KEEP)])
    key = jax.random.PRNGKey(7)
    a = dag_frontier(one, [one.policies()], (0.25,), 150, m_trials=8, key=key)[0]
    b = vector.frontier(MAP_DIST, [KEEP], (0.25,), 8, 150, m_trials=8, key=key)[0]
    for k in ("mean_sojourn", "mean_cost", "p50", "p99", "sojourn_std_err"):
        assert a[k] == pytest.approx(b[k], rel=1e-6), k
    assert a["s/share"] == pytest.approx(1.0)


def test_one_stage_baseline_equals_fleet_rollout_exact_crn():
    """Baseline policy: the one-stage DAG consumes the key exactly like
    `fleet_rollout` (split -> arrivals | draws), so the sample paths match
    to float tolerance (the only difference is cumsum(x)/λ vs cumsum(x/λ))."""
    one = JobDAG([StageSpec("s", 8, MAP_DIST, BASE)])
    key = jax.random.PRNGKey(3)
    res = dag_rollout(one, lam=0.3, n_jobs=120, m_trials=6, key=key)
    ref = vector.fleet_rollout(MAP_DIST, BASE, 0.3, 8, 120, m_trials=6, key=key)
    np.testing.assert_allclose(res.sojourn, ref.sojourn, rtol=1e-5)
    np.testing.assert_allclose(res.service[0], ref.service, rtol=1e-6)
    np.testing.assert_allclose(res.cost[0], ref.cost, rtol=1e-6)
    np.testing.assert_allclose(res.wait[0], ref.wait, rtol=1e-4, atol=1e-4)


def test_one_stage_replicated_matches_fleet_rollout_within_mc():
    one = JobDAG([StageSpec("s", 8, MAP_DIST, KEEP)])
    res = dag_rollout(one, lam=0.25, n_jobs=300, m_trials=24,
                      key=jax.random.PRNGKey(0))
    ref = vector.fleet_rollout(MAP_DIST, KEEP, 0.25, 8, 300, m_trials=24,
                               key=jax.random.PRNGKey(1))
    sigma = max(np.hypot(res.sojourn_std_err, ref.sojourn_std_err), 1e-12)
    assert abs(res.mean_sojourn - ref.mean_sojourn) / sigma < 5.0
    assert res.mean_cost == pytest.approx(ref.mean_cost, abs=0.1)


# ------------------------------------------- fused rollout vs event engine


def test_two_stage_vector_vs_event_within_mc():
    """The tentpole agreement: fused stage-composed rollout ≡ stage-aware
    event engine (aligned per-stage pools) within combined MC error, on
    both E[T] and E[C]."""
    dag = two_stage()
    lam = 0.3
    ev_soj, ev_cost = [], []
    for seed in range(4):
        rep = DagFleetSim(DagFleetConfig(dag, seed=seed)).run(
            poisson_arrivals(400, lam, seed=seed)
        )
        ev_soj.append(rep.stats.mean_sojourn)
        ev_cost.append(rep.stats.mean_cost)
    res = dag_rollout(dag, lam=lam, n_jobs=400, m_trials=32,
                      key=jax.random.PRNGKey(5))
    sigma = max(
        float(np.hypot(np.std(ev_soj) / np.sqrt(len(ev_soj)), res.sojourn_std_err)),
        1e-12,
    )
    assert abs(float(np.mean(ev_soj)) - res.mean_sojourn) / sigma < 5.0
    assert float(np.mean(ev_cost)) == pytest.approx(res.mean_cost, abs=0.1)


def test_event_engine_barrier_semantics():
    """A linear DAG job re-enters the queue per stage: the reduce record's
    release time IS the map record's finish, per job."""
    dag = two_stage()
    rep = DagFleetSim(DagFleetConfig(dag)).run(poisson_arrivals(60, 0.2, seed=2))
    for rec in rep.jobs:
        m, r = rec.stages["map"], rec.stages["reduce"]
        assert r.arrival == pytest.approx(m.finish)
        assert rec.finish == pytest.approx(r.finish)
        assert rec.cost == pytest.approx(m.cost + r.cost)
        assert rec.sojourn >= m.sojourn
    # per-stage pools never over-commit
    assert rep.stats.stage["map"].n_jobs == 60
    assert rep.stats.stage["reduce"].n_jobs == 60


def test_event_fan_in_barrier():
    """Fan-in: the reduce stage releases only after BOTH map stages."""
    dag = JobDAG([
        StageSpec("m1", 4, MAP_DIST, KEEP, c=2),
        StageSpec("m2", 4, RED_DIST, c=2),
        StageSpec("r", 2, RED_DIST, deps=("m1", "m2")),
    ])
    rep = DagFleetSim(DagFleetConfig(dag)).run(poisson_arrivals(50, 0.15, seed=3))
    for rec in rep.jobs:
        release = rec.stages["r"].arrival
        assert release == pytest.approx(
            max(rec.stages["m1"].finish, rec.stages["m2"].finish)
        )
    assert sum(rep.stats.critical_path_shares.values()) == pytest.approx(1.0)


# ----------------------------------------------- pathwise DAG properties


def test_barrier_monotonicity_pathwise():
    """Adding a stage never reduces E[T]: within one rollout, the job's
    completion is bounded below by every stage's barrier — so the 2-stage
    sojourn dominates the 1-stage sojourn job by job, not just on average."""
    dag = two_stage()
    res = dag_rollout(dag, lam=0.3, n_jobs=200, m_trials=8,
                      key=jax.random.PRNGKey(11))
    one_stage_sojourn = res.finish[0] - res.arrivals  # map barrier alone
    assert np.all(np.asarray(res.finish[1] - res.finish[0]) >= -1e-9)
    assert np.all(np.asarray(res.sojourn - one_stage_sojourn) >= -1e-9)
    # and the barrier feeds the next queue: reduce never starts early
    assert np.all(np.asarray(res.ready[1] - res.finish[0]) >= -1e-9)
    assert np.all(np.asarray(res.start - res.ready) >= -1e-9)


def test_critical_path_shares_sum_to_one():
    dag = JobDAG([
        StageSpec("m1", 4, MAP_DIST, KEEP, c=2),
        StageSpec("m2", 4, RED_DIST, c=2),
        StageSpec("r", 2, RED_DIST, deps=("m1", "m2")),
    ])
    res = dag_rollout(dag, lam=0.2, n_jobs=150, m_trials=8,
                      key=jax.random.PRNGKey(13))
    shares = res.stage_shares()
    assert sum(shares.values()) == pytest.approx(1.0, abs=1e-5)
    assert all(v >= 0.0 for v in shares.values())
    # pathwise: attributions telescope to the sojourn exactly
    np.testing.assert_allclose(
        np.asarray(res.attr).sum(axis=0), np.asarray(res.sojourn), rtol=1e-5
    )
    # frontier rows carry the same shares
    row = dag_frontier(dag, [dag.policies()], (0.2,), 150, m_trials=8,
                       key=jax.random.PRNGKey(13))[0]
    total = row["m1/share"] + row["m2/share"] + row["r/share"]
    assert total == pytest.approx(1.0, abs=1e-5)


# ------------------------------------------------------- engine knobs


def test_kernel_matches_scan():
    """kernel=True routes every stage queue through the Pallas kw_queue
    kernel on identical draws: results match the scan path at 1e-5."""
    dag = two_stage()
    key = jax.random.PRNGKey(6)
    scan = dag_frontier(dag, [dag.policies(), (KILL, BASE)], (0.35,), 120,
                        m_trials=8, key=key)
    kern = dag_frontier(dag, [dag.policies(), (KILL, BASE)], (0.35,), 120,
                        m_trials=8, key=key, kernel=True)
    for a, b in zip(scan, kern):
        assert a["mean_sojourn"] == pytest.approx(b["mean_sojourn"], rel=1e-5)
        assert a["mean_cost"] == pytest.approx(b["mean_cost"], rel=1e-5)
        assert a["map/share"] == pytest.approx(b["map/share"], rel=1e-4)


def test_padding_and_rcap_invariance():
    dag = two_stage()
    key = jax.random.PRNGKey(8)
    vecs = [dag.policies(), (BASE, BASE), (KILL, KEEP)]
    base = dag_frontier(dag, vecs, (0.3,), 100, m_trials=8, key=key,
                        pad_cells=False)
    padded = dag_frontier(dag, vecs, (0.3,), 100, m_trials=8, key=key,
                          pad_cells=True)
    for a, b in zip(base, padded):
        assert a["mean_sojourn"] == pytest.approx(b["mean_sojourn"], rel=1e-6)
    # widening r_caps only reshapes the masked fresh draws: estimates move
    # within MC error, never in expectation
    wide = dag_frontier(dag, vecs, (0.3,), 100, m_trials=8, key=key,
                        r_caps=(4, 4))
    for a, b in zip(base, wide):
        sigma = max(np.hypot(a["sojourn_std_err"], b["sojourn_std_err"]), 1e-12)
        assert abs(a["mean_sojourn"] - b["mean_sojourn"]) / sigma < 5.0
    with pytest.raises(ValueError, match="r_cap"):
        dag_frontier(dag, vecs, (0.3,), 100, m_trials=8, r_caps=(1, 1))
    with pytest.raises(ValueError, match="lam"):
        dag_frontier(dag, vecs, (0.0,), 100, m_trials=8)
    with pytest.raises(ValueError, match="policy vector"):
        dag_frontier(dag, [(BASE,)], (0.3,), 100, m_trials=8)


def test_empirical_stage_dists():
    """Per-stage trace slices drive the traced empirical path."""
    rng = np.random.default_rng(0)
    map_trace = rng.exponential(1.0, 400) + 1.0
    red_trace = rng.uniform(0.5, 2.0, 300)
    dag = JobDAG.map_reduce(8, 4, map_trace, red_trace, map_policy=KEEP,
                            c_map=2, c_reduce=2)
    res = dag_rollout(dag, lam=0.25, n_jobs=150, m_trials=8,
                      key=jax.random.PRNGKey(2))
    assert res.mean_sojourn > 0
    rep = DagFleetSim(DagFleetConfig(dag)).run(poisson_arrivals(150, 0.25))
    sigma = max(
        float(np.hypot(rep.stats.sojourn_std_err, res.sojourn_std_err)), 1e-12
    )
    assert abs(rep.stats.mean_sojourn - res.mean_sojourn) / sigma < 5.0


# ------------------------------------------------------------- search


SEARCH_CANDS = [BASE, SingleForkPolicy(0.1, 1, True), KILL]


def test_coordinate_search_improves_and_converges():
    dag = two_stage(map_policy=BASE, reduce_policy=BASE)
    out = coordinate_search(dag, SEARCH_CANDS, lam=0.3, n_jobs=128,
                            m_trials=8, key=jax.random.PRNGKey(4))
    assert out["converged"]
    assert out["n_evals"] > 0
    # CRN-consistent: the reported best is reproducible from dag_frontier
    row = dag_frontier(dag, [out["best"]["policies"]], (0.3,), 128,
                       m_trials=8, key=jax.random.PRNGKey(4),
                       r_caps=(2, 2))[0]
    assert row["mean_sojourn"] == pytest.approx(
        out["best"]["mean_sojourn"], rel=1e-6
    )


def test_coordinate_search_escapes_unstable_incumbent():
    """The ρ-guard outranks the objective: starting from an incumbent the
    fleet cannot absorb (ρ ≥ ρ_max), coordinate ascent must move to a
    stable vector when one exists — even at a worse objective — matching
    exhaustive_search's veto on the same grid."""
    hot = ShiftedExp(0.2, 3.0)
    dag = JobDAG.map_reduce(8, 4, hot, hot, c_map=1, c_reduce=1)
    cands = [BASE, SingleForkPolicy(0.3, 2, True)]
    kw = dict(lam=0.88, n_jobs=192, m_trials=12, key=jax.random.PRNGKey(1),
              objective="cost")
    co = coordinate_search(dag, cands, init=(BASE, BASE), **kw)
    assert co["best"]["rho"] < 0.95, "must escape the unstable baseline"
    ex = exhaustive_search(dag, cands, **kw)
    assert ex["best"]["rho"] < 0.95


def test_stage_scheduler_cannot_run_standalone():
    """A DAG stage scheduler shares its heap: popping through its OwnedHeap
    view (what a direct FleetScheduler.run() would do) must refuse rather
    than hand it another stage's events."""
    from repro.dag.engine import DagFleetScheduler

    sched = DagFleetScheduler(two_stage())
    sched._done = [set()]
    sched._release(0, 0, 0.0)  # a pending event makes the shared heap truthy
    stage0 = sched.stage_scheds[0]
    assert stage0.heap  # truthiness reflects the SHARED heap
    with pytest.raises(RuntimeError, match="shares its event heap"):
        stage0.run([])


@pytest.mark.slow
def test_exhaustive_search_dominates_uniform():
    """The joint per-stage optimum can only improve on the uniform slice of
    its own grid (shared CRN makes this exact, not statistical)."""
    dag = two_stage(map_policy=BASE, reduce_policy=BASE)
    key = jax.random.PRNGKey(9)
    out = exhaustive_search(dag, SEARCH_CANDS, lam=0.3, n_jobs=192,
                            m_trials=12, key=key)
    assert out["n_cells"] == len(SEARCH_CANDS) ** 2
    uni_rows = dag_frontier(dag, uniform_vectors(dag, SEARCH_CANDS), (0.3,),
                            192, m_trials=12, key=key, r_caps=(2, 2))
    best_uniform = min(uni_rows, key=lambda r: r["mean_sojourn"])
    assert out["best"]["mean_sojourn"] <= best_uniform["mean_sojourn"] + 1e-9


@pytest.mark.slow
def test_exhaustive_and_coordinate_agree_on_small_grid():
    dag = two_stage(map_policy=BASE, reduce_policy=BASE)
    key = jax.random.PRNGKey(10)
    ex = exhaustive_search(dag, SEARCH_CANDS, lam=0.25, n_jobs=160,
                           m_trials=12, key=key)
    co = coordinate_search(dag, SEARCH_CANDS, lam=0.25, n_jobs=160,
                           m_trials=12, key=key)
    # coordinate ascent can stop at a coordinate-wise local optimum, but it
    # must never end somewhere worse than the incumbent column minimum
    assert co["best"]["mean_sojourn"] <= ex["rows"][-1]["mean_sojourn"]
    ex_labels = {r["label"] for r in ex["rows"]}
    assert co["best"]["label"] in ex_labels


# ------------------------------------------------- stage traces + serving


def test_stage_trace_synthesis():
    from repro.data.traces import STAGE_TRACES, load_stage_trace, load_trace

    m = load_stage_trace("map")
    assert np.mean(m) == pytest.approx(1.0, rel=1e-6)
    raw = load_stage_trace("reduce", normalize=False)
    np.testing.assert_allclose(raw, load_trace(STAGE_TRACES["reduce"]))
    with pytest.raises(KeyError, match="shuffle|unknown"):
        load_stage_trace("not-a-stage")
    # map (job1) is heavier-tailed than reduce (job3) once normalized —
    # the asymmetry the per-stage policy split exploits
    r = load_stage_trace("reduce")
    assert np.max(m) / np.mean(m) > np.max(r) / np.mean(r)


def test_fleet_hedged_server_dag_mode():
    from repro.runtime import FleetHedgedServer

    dag = two_stage()
    srv = FleetHedgedServer(dag=dag, serve_fn=lambda r: r * 2)
    batches = [[1, 2, 3]] * 20
    outcomes, stats = srv.serve_stream(batches, rate=0.3, seed=0)
    assert [o.values for o in outcomes] == [[2, 4, 6]] * 20
    assert all(o.finish >= o.start >= o.arrival for o in outcomes)
    assert sum(stats.critical_path_shares.values()) == pytest.approx(1.0)
    with pytest.raises(ValueError, match="stage specs"):
        FleetHedgedServer(dag=dag, capacity=8, serve_fn=lambda r: r)
    # single-pool knobs are rejected, not silently dropped
    with pytest.raises(ValueError, match="stage specs"):
        FleetHedgedServer(dag=dag, serve_fn=lambda r: r, policy=KEEP)
    with pytest.raises(ValueError, match="stage specs"):
        FleetHedgedServer(dag=dag, serve_fn=lambda r: r, adapt=False)
    with pytest.raises(ValueError, match="stage specs"):
        FleetHedgedServer(dag=dag, serve_fn=lambda r: r, placement="aligned")


def test_public_exports():
    import repro.dag as dag_mod
    import repro.fleet as fleet_mod

    for name in ("frontier", "policy_search", "sweep", "fleet_rollout"):
        assert name in fleet_mod.__all__ and hasattr(fleet_mod, name)
    for name in ("JobDAG", "StageSpec", "dag_frontier", "dag_rollout",
                 "DagFleetSim", "coordinate_search", "exhaustive_search"):
        assert name in dag_mod.__all__ and hasattr(dag_mod, name)
