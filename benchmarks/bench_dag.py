"""DAG economics: the fused stage-composed rollout vs the per-stage event
engine, and joint per-stage search vs the best uniform policy.

Measurements:
  * the tentpole gate: a (policy-vector × λ) grid on a two-stage
    map→reduce DAG evaluated by `dag.rollout.dag_frontier` (the whole grid
    as ONE fused device program chaining masked_single_fork through the
    barrier per stage) raced against the stage-aware event engine
    (`DagFleetSim`: one FleetScheduler per stage pool on a shared heap) on
    the SAME grid — gated on ≥10× speedup AND ≤5σ agreement on E[T] and
    E[C] at every shared cell;
  * joint-search quality: the exhaustive per-stage product grid must find
    a vector strictly dominating (lower E[T] AND lower E[C]) the best
    uniform single-stage policy on the heterogeneous map/reduce demo
    (map = heavy-tailed job1 trace, reduce = tail-shortened job3) — the
    stage-coupled effect a single-stage planner cannot see;
  * critical-path attribution across load for the chosen vector (the
    map-vs-reduce table EXPERIMENTS.md quotes);
  * kernel parity: the Pallas kw_queue stage-queue path vs the scan path
    on one shared grid (exactness is a test concern; here we record the
    wall-clock of both for the trajectory).

Artifact: benchmarks/results/dag_frontier.json; gate outcomes land in the
repo-root BENCH_fleet.json perf trajectory (benchmarks/run.py).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import ShiftedExp, SingleForkPolicy
from repro.dag import (
    DagFleetConfig,
    DagFleetSim,
    JobDAG,
    best_stable,
    dag_frontier,
    dag_rollout,
    exhaustive_search,
    poisson_arrivals,
    uniform_vectors,
)
from repro.data.traces import load_stage_trace

from .common import GateFailure, record_gate, save_json

# analytic two-stage DAG for the engine race (hashable dists: one compile)
MAP_DIST = ShiftedExp(1.0, 1.0)
RED_DIST = ShiftedExp(0.5, 2.0)
N_MAP, N_RED = 8, 4
C_MAP, C_RED = 2, 2
N_JOBS = 400
M_TRIALS = 12
LAMS = (0.2, 0.3, 0.4)
# every fork stays within its stage's gang block (keep: s·r ≤ n−s) so the
# aligned event engine never truncates replicas — same convention as
# bench_fleet's single-stage grids
BASE = SingleForkPolicy(0.0, 0, True)
VECTORS = (
    (BASE, BASE),
    (SingleForkPolicy(0.2, 1, True), BASE),
    (SingleForkPolicy(0.2, 1, True), SingleForkPolicy(0.25, 1, True)),
    (SingleForkPolicy(0.25, 1, False), SingleForkPolicy(0.25, 1, True)),
)
SPEEDUP_FLOOR = 10.0

# joint-search demo geometry (mirrors examples/dag_pipeline.py)
SEARCH_LAM = 0.55
SEARCH_CANDS = (
    BASE,
    SingleForkPolicy(0.05, 1, True),
    SingleForkPolicy(0.1, 1, True),
    SingleForkPolicy(0.1, 2, True),
    SingleForkPolicy(0.1, 1, False),
    SingleForkPolicy(0.2, 1, True),
)


def _dag():
    return JobDAG.map_reduce(
        N_MAP, N_RED, MAP_DIST, RED_DIST, c_map=C_MAP, c_reduce=C_RED
    )


def _json_rows(rows: list[dict]) -> list[dict]:
    """Frontier rows carry the policy objects under 'policies'; swap them
    for their labels so the artifact serializes."""
    return [
        {k: ([p.label() for p in v] if k == "policies" else v) for k, v in r.items()}
        for r in rows
    ]


def _event_grid(dag) -> list[dict]:
    rows = []
    for vec in VECTORS:
        for lam in LAMS:
            rep = DagFleetSim(DagFleetConfig(dag, policies=vec)).run(
                poisson_arrivals(N_JOBS, lam, seed=int(lam * 1e3))
            )
            rows.append(
                dict(
                    lam=lam,
                    policies=[p.label() for p in vec],
                    mean_sojourn=rep.stats.mean_sojourn,
                    mean_cost=rep.stats.mean_cost,
                    sojourn_std_err=rep.stats.sojourn_std_err,
                    shares=rep.stats.critical_path_shares,
                )
            )
    return rows


def run():
    rows = []
    failures = []
    dag = _dag()
    key = jax.random.PRNGKey(17)
    r_caps = (2, 2)

    # -- tentpole: fused stage-composed grid vs the per-stage event engine --
    dag_frontier(dag, VECTORS, LAMS, N_JOBS, m_trials=M_TRIALS, key=key,
                 r_caps=r_caps)  # warm the one fused compilation
    speedup, event_s, fused_s = 0.0, 0.0, 0.0
    for attempt in range(3):
        t0 = time.perf_counter()
        event_rows = _event_grid(dag)
        attempt_event_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        fused_rows = dag_frontier(
            dag, VECTORS, LAMS, N_JOBS, m_trials=M_TRIALS, key=key, r_caps=r_caps
        )
        attempt_fused_s = time.perf_counter() - t0
        if attempt_event_s / max(attempt_fused_s, 1e-9) > speedup:
            speedup = attempt_event_s / max(attempt_fused_s, 1e-9)
            event_s, fused_s = attempt_event_s, attempt_fused_s
        if speedup >= SPEEDUP_FLOOR:
            break
    if not record_gate(
        "dag_fused_vs_event_speedup", speedup >= SPEEDUP_FLOOR,
        f"{speedup:.1f}x (floor {SPEEDUP_FLOOR}x; event={event_s:.2f}s "
        f"fused={fused_s:.2f}s, {len(VECTORS)}x{len(LAMS)} cells)",
    ):
        failures.append(
            f"fused DAG grid only {speedup:.1f}x faster than the stage-aware "
            f"event engine (floor {SPEEDUP_FLOOR}x; event={event_s:.2f}s "
            f"fused={fused_s:.2f}s)"
        )
    # agreement on EVERY shared cell, in combined-MC-sigma units; the fused
    # path simulates M_TRIALS fleets per cell vs the event path's one
    worst_soj, worst_cost = 0.0, 0.0
    for f, e in zip(fused_rows, event_rows):
        sigma = max(float(np.hypot(f["sojourn_std_err"], e["sojourn_std_err"])), 1e-12)
        worst_soj = max(worst_soj, abs(f["mean_sojourn"] - e["mean_sojourn"]) / sigma)
        worst_cost = max(worst_cost, abs(f["mean_cost"] - e["mean_cost"]))
    if not record_gate(
        "dag_fused_vs_event_agreement", worst_soj <= 5.0 and worst_cost <= 0.1,
        f"max_sojourn_dev={worst_soj:.2f}sigma max_cost_dev={worst_cost:.4f} "
        f"over {len(fused_rows)} shared cells",
    ):
        failures.append(
            f"fused DAG grid disagrees with the event engine: worst cell "
            f"sojourn off by {worst_soj:.1f} sigma, cost by {worst_cost:.4f}"
        )
    rows.append(
        ("dag_grid_event", event_s * 1e6 / len(event_rows), f"cells={len(event_rows)}")
    )
    rows.append(
        ("dag_grid_fused", fused_s * 1e6 / len(fused_rows),
         f"speedup={speedup:.1f}x;max_dev={worst_soj:.2f}sigma")
    )

    # -- joint per-stage search strictly dominates the best uniform policy --
    demo = JobDAG.map_reduce(
        8, 4, load_stage_trace("map"), load_stage_trace("reduce"),
        c_map=2, c_reduce=1,
    )
    skey = jax.random.PRNGKey(0)
    t0 = time.perf_counter()
    ex = exhaustive_search(
        demo, list(SEARCH_CANDS), lam=SEARCH_LAM, n_jobs=256, m_trials=16, key=skey
    )
    search_s = time.perf_counter() - t0
    uni_rows = dag_frontier(
        demo, uniform_vectors(demo, SEARCH_CANDS), (SEARCH_LAM,), 256,
        m_trials=16, key=skey, r_caps=(3, 3),
    )
    uniform = best_stable(uni_rows)  # same ρ-guarded argmin the search uses
    joint = ex["best"]
    dominates = (
        joint["mean_sojourn"] < uniform["mean_sojourn"]
        and joint["mean_cost"] < uniform["mean_cost"]
    )
    if not record_gate(
        "dag_joint_dominates_uniform", dominates,
        f"joint[{joint['label']}] T={joint['mean_sojourn']:.3f} "
        f"C={joint['mean_cost']:.3f} vs uniform[{uniform['label']}] "
        f"T={uniform['mean_sojourn']:.3f} C={uniform['mean_cost']:.3f}",
    ):
        failures.append(
            f"joint per-stage search ({joint['label']}) does not strictly "
            f"dominate the best uniform policy ({uniform['label']})"
        )
    rows.append(
        ("dag_joint_search", search_s * 1e6 / ex["n_cells"],
         f"cells={ex['n_cells']};joint_T={joint['mean_sojourn']:.3f};"
         f"uniform_T={uniform['mean_sojourn']:.3f}")
    )

    # -- critical-path table for the chosen vector across load --------------
    crit_lams = (0.2, 0.35, 0.55, 0.75, 0.9)
    crit_rows = dag_frontier(
        demo, [joint["policies"]], crit_lams, 256, m_trials=16, key=skey,
        r_caps=(3, 3),
    )
    crit = {
        r["lam"]: dict(map=r["map/share"], reduce=r["reduce/share"],
                       sojourn=r["mean_sojourn"])
        for r in crit_rows
    }
    rows.append(
        ("dag_critical_path", 0.0,
         ";".join(f"lam={l}:reduce={c['reduce']:.2f}" for l, c in crit.items()))
    )

    # -- kernel vs scan wall-clock on the stage queues ----------------------
    kkey = jax.random.PRNGKey(23)
    for kernel in (False, True):  # warm both compilations
        dag_frontier(dag, VECTORS, LAMS, N_JOBS, m_trials=M_TRIALS, key=kkey,
                     r_caps=r_caps, kernel=kernel)
    t0 = time.perf_counter()
    dag_frontier(dag, VECTORS, LAMS, N_JOBS, m_trials=M_TRIALS, key=kkey,
                 r_caps=r_caps, kernel=False)
    scan_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    dag_frontier(dag, VECTORS, LAMS, N_JOBS, m_trials=M_TRIALS, key=kkey,
                 r_caps=r_caps, kernel=True)
    kern_s = time.perf_counter() - t0
    rows.append(
        ("dag_stage_queue_scan", scan_s * 1e6, "per full grid")
    )
    rows.append(
        ("dag_stage_queue_kernel", kern_s * 1e6,
         f"interpret_on_cpu;scan/kernel={scan_s / max(kern_s, 1e-9):.2f}x")
    )

    # one-cell rollout for the artifact's stage-level detail
    detail = dag_rollout(
        dag, lam=LAMS[1], n_jobs=N_JOBS, m_trials=M_TRIALS,
        policies=VECTORS[1], key=key,
    )
    save_json(
        "dag_frontier",
        dict(
            grid=dict(
                lams=list(LAMS),
                vectors=[[p.label() for p in v] for v in VECTORS],
                n_map=N_MAP, n_reduce=N_RED, c_map=C_MAP, c_reduce=C_RED,
                n_jobs=N_JOBS, m_trials=M_TRIALS,
            ),
            event=event_rows,
            fused=_json_rows(fused_rows),
            timing=dict(event_s=event_s, fused_s=fused_s, speedup=speedup),
            agreement=dict(
                max_sojourn_dev_sigma=worst_soj, max_cost_dev=worst_cost
            ),
            joint_search=dict(
                lam=SEARCH_LAM,
                candidates=[p.label() for p in SEARCH_CANDS],
                n_cells=ex["n_cells"],
                search_s=search_s,
                joint=dict(label=joint["label"], T=joint["mean_sojourn"],
                           C=joint["mean_cost"], rho=joint["rho"]),
                uniform=dict(label=uniform["label"], T=uniform["mean_sojourn"],
                             C=uniform["mean_cost"]),
                dominates=dominates,
            ),
            critical_path=crit,
            rollout_detail=detail.summary(),
            kernel_timing=dict(scan_s=scan_s, kernel_s=kern_s),
        ),
    )
    if failures:
        raise GateFailure("; ".join(failures), rows)
    return rows
