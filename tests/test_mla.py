"""MLA (DeepSeek latent attention): absorbed decode == naive decode, and
latent-cache geometry."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import Tape
from repro.models.mla import MLASpec, init_mla, mla_decode, mla_full

KEY = jax.random.PRNGKey(0)


def _setup(dtype=jnp.float32):
    spec = MLASpec(d_model=64, n_heads=4, q_lora=32, kv_lora=16, d_nope=16, d_rope=8, d_v=16)
    tape = Tape(KEY, dtype=dtype)
    init_mla(tape, spec)
    return spec, tape.params


def test_absorbed_equals_naive_decode():
    """Matrix absorption is an algebraic identity: logits must match."""
    spec, params = _setup()
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, spec.d_model))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    _, (ckv, kpe) = mla_full(params, spec, x, pos, impl="ref")
    # grow cache by one slot and decode the next token both ways
    ckv = jnp.pad(ckv, ((0, 0), (0, 1), (0, 0)))
    kpe = jnp.pad(kpe, ((0, 0), (0, 1), (0, 0)))
    x_new = jax.random.normal(jax.random.PRNGKey(2), (B, 1, spec.d_model))
    out_naive, _, _ = mla_decode(params, spec, x_new, ckv, kpe, S, impl="naive")
    out_abs, _, _ = mla_decode(params, spec, x_new, ckv, kpe, S, impl="absorbed")
    np.testing.assert_allclose(
        np.asarray(out_naive, np.float32), np.asarray(out_abs, np.float32),
        atol=1e-4, rtol=1e-4,
    )


def test_latent_cache_is_compressed():
    """The MLA cache stores kv_lora + d_rope dims per token — far smaller
    than 2*H*head_dim (the paper's 93% KV-cache reduction)."""
    spec, params = _setup()
    B, S = 1, 8
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, spec.d_model))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    _, (ckv, kpe) = mla_full(params, spec, x, pos, impl="ref")
    assert ckv.shape == (B, S, spec.kv_lora)
    assert kpe.shape == (B, S, spec.d_rope)
    full_kv_dims = 2 * spec.n_heads * (spec.d_nope + spec.d_rope)
    assert spec.cache_dim < full_kv_dims / 3


def test_decode_matches_full_forward_last_position():
    spec, params = _setup()
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, spec.d_model))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out_full, _ = mla_full(params, spec, x, pos, impl="ref")
    _, (ckv, kpe) = mla_full(params, spec, x[:, : S - 1], pos[:, : S - 1], impl="ref")
    ckv = jnp.pad(ckv, ((0, 0), (0, 1), (0, 0)))
    kpe = jnp.pad(kpe, ((0, 0), (0, 1), (0, 0)))
    for impl in ("naive", "absorbed"):
        out_dec, _, _ = mla_decode(params, spec, x[:, S - 1 :], ckv, kpe, S - 1, impl=impl)
        np.testing.assert_allclose(
            np.asarray(out_full[:, -1:], np.float32), np.asarray(out_dec, np.float32),
            atol=2e-3, rtol=2e-3,
        )
