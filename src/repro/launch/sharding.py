"""Logical-axis -> mesh resolution with divisibility fallback.

Params are declared with logical axes ('fsdp', 'model', 'layers', None) by
`repro.models.common.Tape`; activations/caches use ('batch', 'heads', ...).
A dim is sharded only if its size divides the product of the target mesh
axes — otherwise it silently falls back to replication (this is how e.g.
gemma's 8 query heads survive a 16-way model axis: the flattened q_dim
2048 shards instead, and the head dim stays replicated).

Two rule sets:
  * TRAIN: FSDP ('fsdp' -> all batch axes) + TP ('model').
  * SERVE_STATIONARY: weights stationary — 'fsdp' dims replicated so decode
    never regathers weights (the §Perf alternative for decode cells; the
    baseline serve path reuses TRAIN rules, which is exactly what makes it
    collective-bound — see EXPERIMENTS.md).
"""

from __future__ import annotations

import math
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def rules_train(mesh: Mesh) -> dict:
    bd = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return {
        "batch": bd,
        "fsdp": bd,
        "model": ("model",),
        "heads": ("model",),
        "vocab": ("model",),
        "layers": None,
    }


def rules_serve_stationary(mesh: Mesh) -> dict:
    r = rules_train(mesh)
    r["fsdp"] = None  # weights stationary: no per-step regather
    return r


def resolve_spec(
    axes: Sequence[Optional[str]], shape: Sequence[int], mesh: Mesh, rules: dict
) -> P:
    parts = []
    for dim, ax in zip(shape, axes):
        target = rules.get(ax) if ax is not None else None
        if target is None:
            parts.append(None)
            continue
        if isinstance(target, str):
            target = (target,)
        if dim % _axes_size(mesh, target) == 0:
            parts.append(target if len(target) > 1 else target[0])
        else:
            parts.append(None)  # divisibility fallback -> replicate
    return P(*parts)


def tree_shardings(spec_tree: PyTree, shape_tree: PyTree, mesh: Mesh, rules: dict) -> PyTree:
    """Map a logical-axes tree + shapes tree -> NamedSharding tree."""

    def one(axes, arr):
        return NamedSharding(mesh, resolve_spec(axes, arr.shape, mesh, rules))

    return jax.tree.map(one, spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x))


def param_shardings(specs: PyTree, params: PyTree, mesh: Mesh, rules: dict) -> PyTree:
    return tree_shardings(specs, params, mesh, rules)


def batch_sharding(mesh: Mesh, shape: Sequence[int], rules: dict) -> NamedSharding:
    """Leading-dim batch sharding with fallback for non-divisible batch."""
    bd = rules["batch"]
    if bd is not None and shape[0] % _axes_size(mesh, bd) == 0:
        return NamedSharding(mesh, P(bd if len(bd) > 1 else bd[0], *([None] * (len(shape) - 1))))
    return NamedSharding(mesh, P(*([None] * len(shape))))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
