"""End-to-end driver: train a ~100M-class LM for a few hundred steps under
the straggler-aware runtime (speculative gradient-shard replication, online
policy adaptation, failures, checkpoints).

    PYTHONPATH=src python examples/straggler_training.py

This is a thin preset over ``repro.launch.train``; see that module for the
full CLI (any of the 10 assigned --arch values works).
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    main(
        [
            "--arch", "qwen2-0.5b",
            "--steps", "200",
            "--batch", "8",
            "--seq", "128",
            "--n-tasks", "8",
            "--dist", "pareto",
            "--checkpoint-dir", "/tmp/repro_ckpt",
            "--log-every", "20",
        ]
        + sys.argv[1:]
    )
