"""Assigned input shapes and per-(arch x shape) applicability.

  train_4k     seq 4096,   global_batch 256   (training)
  prefill_32k  seq 32768,  global_batch 32    (inference prefill)
  decode_32k   seq 32768,  global_batch 128   (one token, 32k KV cache)
  long_500k    seq 524288, global_batch 1     (long-context decode;
               SSM/hybrid archs only — full-attention archs skip, see
               DESIGN.md §4)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.lm import ModelConfig, build_model


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SHAPE_NAMES = tuple(SHAPES)


def applicability(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-skipped)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k requires sub-quadratic attention (SSM/hybrid only)"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill -> batch dict; decode -> (cache, tokens, position) where
    the cache comes from eval_shape over prefill (no allocation).
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = {}
        text = S - cfg.vision_patches if cfg.family == "vlm" else S
        batch["tokens"] = _sds((B, text), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = _sds((B, text), jnp.int32)
        if cfg.family == "vlm":
            batch["vision_embeds"] = _sds((B, cfg.vision_patches, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            batch["enc_embeds"] = _sds((B, cfg.enc_positions, cfg.d_model), jnp.bfloat16)
        return batch

    # decode: cache shapes from an abstract prefill at full cache length
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), abstract=True)
    text = S - cfg.vision_patches if cfg.family == "vlm" else S
    pre_batch = {"tokens": _sds((B, text), jnp.int32)}
    if cfg.family == "vlm":
        pre_batch["vision_embeds"] = _sds((B, cfg.vision_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        pre_batch["enc_embeds"] = _sds((B, cfg.enc_positions, cfg.d_model), jnp.bfloat16)
    _, cache = jax.eval_shape(model.prefill, params, pre_batch)
    return {
        "cache": cache,
        "tokens": _sds((B,), jnp.int32),
        "position": _sds((), jnp.int32),
    }
