# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   PYTHONPATH=src python -m benchmarks.run            # everything
#   PYTHONPATH=src python -m benchmarks.run --only trace table1
#
# Artifacts (full curves/tables) land in benchmarks/results/*.json.  Runs
# that include the fleet or kernels benches additionally write a repo-root
# BENCH_fleet.json perf trajectory (timings, speedups, gate outcomes, git
# sha) so future PRs can diff hot-path regressions against this commit.
import argparse
import json
import sys
import time
import traceback

from . import (
    bench_dag,
    bench_fig3_fig5,
    bench_fig4_fig6,
    bench_fleet,
    bench_kernels,
    bench_roofline,
    bench_runtime,
    bench_scaling,
    bench_table1,
    bench_trace,
)
from .common import GATES, REPO_ROOT, emit, git_sha

BENCHES = {
    "fig3_fig5": bench_fig3_fig5,  # sim vs analytic latency (Figs. 3, 5)
    "fig4_fig6": bench_fig4_fig6,  # E[T]/E[C]/trade-off sweeps (Figs. 4, 6)
    "trace": bench_trace,  # bootstrap trade-offs on traces (Figs. 7-10)
    "table1": bench_table1,  # policy optimization (Table 1)
    "scaling": bench_scaling,  # Corollary 1 growth exponents
    "kernels": bench_kernels,  # Pallas kernels + Algorithm 1 throughput
    "runtime": bench_runtime,  # trainer/serving economics
    "fleet": bench_fleet,  # multi-job finite-capacity frontier
    "dag": bench_dag,  # multi-stage DAG jobs: fused stage rollout + joint search
    "roofline": bench_roofline,  # dry-run roofline summary
}

#: benches whose rows/gates feed the repo-root perf trajectory
TRAJECTORY_BENCHES = ("fleet", "kernels", "dag")


def _write_trajectory(results: dict) -> None:
    """BENCH_fleet.json at the repo root: the hot-path perf record this
    commit leaves behind (written even when a gate failed, so regressions
    are diagnosable from the artifact alone).  `ok` covers only the
    trajectory benches — an unrelated bench failing elsewhere in the run
    must not read as a hot-path regression.

    Partial runs merge: `--only dag` refreshes the dag entry (and the
    gates that run recorded) while keeping the other trajectory benches'
    rows and gate outcomes from the existing file, so iterating on one
    bench never erases the baselines future PRs diff against.  `ok` /
    `all_gates_passed` are recomputed over the merged content."""
    path = REPO_ROOT / "BENCH_fleet.json"
    benches = {}
    gates = list(GATES)
    if path.exists():
        try:
            prev = json.loads(path.read_text())
            benches = {
                k: v for k, v in prev.get("benches", {}).items()
                if k in TRAJECTORY_BENCHES
            }
            fresh = {g["name"] for g in gates}
            gates = [
                g for g in prev.get("gates", []) if g["name"] not in fresh
            ] + gates
        except (json.JSONDecodeError, OSError):
            pass  # corrupt/unreadable: rebuild from this run alone
    benches.update(
        {
            name: dict(
                rows=[dict(name=r[0], us_per_call=r[1], derived=r[2]) for r in rows],
                error=err,
            )
            for name, (rows, err) in results.items()
        }
    )
    payload = dict(
        git_sha=git_sha(),
        generated_unix=time.time(),
        benches=benches,
        gates=gates,
        all_gates_passed=all(g["passed"] for g in gates),
        ok=all(b.get("error") is None for b in benches.values()),
    )
    path.write_text(json.dumps(payload, indent=1, default=float))
    print(f"# perf trajectory -> {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None, choices=list(BENCHES))
    args = ap.parse_args()
    names = args.only or list(BENCHES)
    print("name,us_per_call,derived")
    failed = 0
    results: dict[str, tuple[list, str | None]] = {}
    for name in names:
        t0 = time.time()
        rows: list = []
        err = None
        try:
            rows = BENCHES[name].run()
            emit(rows)
        except Exception as e:
            failed += 1
            traceback.print_exc()
            err = f"{type(e).__name__}: {e}"
            rows = list(getattr(e, "rows", []))  # GateFailure keeps measurements
            emit(rows)
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}")
        if name in TRAJECTORY_BENCHES:
            results[name] = (rows, err)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if results:
        _write_trajectory(results)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
