"""Online policy adaptation (paper §5.2 'future directions', built here).

A real deployment does not know F_X a priori.  `OnlinePolicyController`
learns it from streaming task-completion telemetry and periodically re-runs
the bootstrap optimizer, with ε-greedy exploration over r (the multi-arm
bandit flavor the paper sketches):

  * every completed task contributes one execution-time sample (reservoir
    sampled to a bounded window so drifting clusters stay tracked);
  * every `reoptimize_every` completed *jobs* (steps), re-run Algorithm 1 +
    §4.3 optimization on the current window;
  * with prob. ε, perturb r by ±1 (clamped to [0, r_max]) to keep exploring;
    from BASELINE the perturbation is a small-p single fork instead, so the
    controller is never stuck at p = 0 with no way to gather counter-evidence.

The controller is deliberately framework-agnostic: the training runtime
(`repro.runtime`) feeds it samples and asks `current_policy()` each step.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import optimize
from .policy import BASELINE, SingleForkPolicy

__all__ = ["OnlinePolicyController"]


@dataclasses.dataclass
class OnlinePolicyController:
    objective: str = "latency"  # 'latency' (eq. 19) or 'cost' (eq. 20)
    lam: float = 0.1  # λ for the cost-sensitive objective
    r_max: int = 4
    window: int = 4096  # reservoir size
    min_samples: int = 64  # don't optimize before this many samples
    reoptimize_every: int = 8  # jobs between re-optimizations
    epsilon: float = 0.05  # exploration probability over r
    explore_p: float = 0.05  # fork fraction used when exploring away from baseline
    n_tasks: int | None = None  # per-job task count for eq. 20 (or plumbed per job)
    bootstrap_m: int = 200
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._samples: list[float] = []
        self._seen = 0
        self._jobs = 0
        self._job_n = self.n_tasks  # last job size seen (eq. 20's n)
        self._policy = BASELINE
        self.history: list[SingleForkPolicy] = []

    # ----------------------------------------------------------- telemetry
    def record_task_time(self, seconds: float) -> None:
        """Reservoir-sample one completed task's execution time."""
        self._seen += 1
        if len(self._samples) < self.window:
            self._samples.append(float(seconds))
        else:
            j = int(self._rng.integers(0, self._seen))
            if j < self.window:
                self._samples[j] = float(seconds)

    def record_job_complete(self, n_tasks: int | None = None) -> None:
        if n_tasks is not None:
            self._job_n = int(n_tasks)
        self._jobs += 1
        if (
            self._jobs % self.reoptimize_every == 0
            and len(self._samples) >= self.min_samples
        ):
            self._reoptimize()

    # ------------------------------------------------------------- policy
    def current_policy(self) -> SingleForkPolicy:
        return self._policy

    @property
    def n_samples(self) -> int:
        return len(self._samples)

    def _reoptimize(self) -> None:
        ev = optimize.bootstrap_evaluator(
            np.asarray(self._samples), m=self.bootstrap_m, seed=int(self._rng.integers(2**31))
        )
        # eq. 20's n is the job's task count, plumbed via `n_tasks` /
        # `record_job_complete` — NOT the reservoir size, which grows to
        # `window` and would drown E[T] in a 4096x-weighted cost term
        n = self._job_n if self._job_n else 1
        if self.objective == "latency":
            best, _ = optimize.optimize_latency_sensitive(
                ev, r_max=self.r_max, p_grid=np.arange(0.02, 0.42, 0.04)
            )
        else:
            best, _ = optimize.optimize_cost_sensitive(
                ev, lam=self.lam, n=n, r_max=self.r_max, p_grid=np.arange(0.02, 0.42, 0.04)
            )
        pol = best.policy
        # ε-greedy exploration (bounded): perturb r, or — when the optimizer
        # returned BASELINE — try a small-p fork so the controller can still
        # gather evidence away from p = 0 instead of sticking there forever
        if self._rng.random() < self.epsilon:
            if pol.is_baseline:
                pol = SingleForkPolicy(p=self.explore_p, r=1, keep=True)
            else:
                dr = int(self._rng.choice((-1, 1)))
                r = int(np.clip(pol.r + dr, 0, self.r_max))
                if not (pol.keep and r == 0):
                    pol = SingleForkPolicy(p=pol.p, r=r, keep=pol.keep)
        self._policy = pol
        self.history.append(pol)
