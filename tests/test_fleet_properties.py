"""Property tests for the queueing recursions behind `repro.fleet.vector`:
the closed-form Lindley path (c = 1) and the Kiefer–Wolfowitz G/G/c scan
must satisfy the structural invariants queueing theory promises, for ANY
arrival/service sample path — not just the Poisson/ShiftedExp configs the
agreement tests happen to run.  Plain (non-@given) tests pin the same
invariants on fixed adversarial paths so the file still bites when
hypothesis is absent.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_stubs import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401

from repro.fleet import vector

# strategies: short positive float arrays; one shared shape so inter-arrival
# and service lists zip into jobs
_floats = st.floats(min_value=1e-3, max_value=50.0, allow_nan=False, allow_infinity=False)
_paths = st.lists(st.tuples(_floats, _floats), min_size=1, max_size=40)


def _queues(pairs):
    inter = np.array([p[0] for p in pairs])
    services = np.array([p[1] for p in pairs])
    return jnp.cumsum(jnp.asarray(inter)), jnp.asarray(services)


def _tol(*arrays):
    """float32 scale-aware tolerance: comparisons between two queue runs
    differ by a few ulps of the largest time on the path."""
    hi = max(float(jnp.max(jnp.abs(a))) for a in arrays)
    return 1e-4 + 3e-6 * hi


def _kw(arrivals, services, c, speeds=None):
    if speeds is None:
        speeds = jnp.ones((c,))
    return vector.kw_queue(arrivals, services, speeds)


# ------------------------------------------------------- c = 1 reduction


@given(pairs=_paths)
@settings(max_examples=60, deadline=None)
def test_kw_c1_reduces_to_lindley(pairs):
    """One slot: the KW scan IS the Lindley recursion, path by path."""
    arrivals, services = _queues(pairs)
    s_lin, f_lin = vector.lindley(arrivals, services)
    s_kw, f_kw, svc, slots = _kw(arrivals, services, c=1)
    tol = _tol(f_lin)
    np.testing.assert_allclose(np.asarray(s_kw), np.asarray(s_lin), rtol=1e-5, atol=tol)
    np.testing.assert_allclose(np.asarray(f_kw), np.asarray(f_lin), rtol=1e-5, atol=tol)
    assert np.all(np.asarray(slots) == 0)


def test_kw_c1_reduces_to_lindley_fixed():
    """The same reduction on a fixed bursty path (runs without hypothesis)."""
    arrivals = jnp.array([0.1, 0.1001, 0.1002, 5.0, 5.5])
    services = jnp.array([3.0, 0.01, 4.0, 0.5, 10.0])
    s_lin, f_lin = vector.lindley(arrivals, services)
    s_kw, f_kw, _, _ = _kw(arrivals, services, c=1)
    np.testing.assert_allclose(np.asarray(f_kw), np.asarray(f_lin), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s_kw), np.asarray(s_lin), rtol=1e-6)


# ---------------------------------------------------- basic sanity bounds


@given(pairs=_paths, c=st.integers(min_value=1, max_value=5))
@settings(max_examples=60, deadline=None)
def test_sojourn_ge_service_ge_zero(pairs, c):
    """start >= arrival, service > 0, sojourn = wait + service >= service."""
    arrivals, services = _queues(pairs)
    starts, finishes, svc, _ = _kw(arrivals, services, c=c)
    starts, finishes, svc = map(np.asarray, (starts, finishes, svc))
    a = np.asarray(arrivals)  # float32, same dtype the queue computed in
    tol = _tol(finishes)
    assert np.all(starts >= a)  # start = max(arrival, free): exact in f32
    assert np.all(svc > 0)
    np.testing.assert_allclose(finishes - starts, svc, rtol=1e-5, atol=tol)
    assert np.all(finishes - a >= svc - tol)  # sojourn >= service


@given(pairs=_paths)
@settings(max_examples=40, deadline=None)
def test_heterogeneous_speeds_scale_service(pairs):
    """Whatever slot serves a job, its service stretches by exactly that
    slot's speed; slot indices stay in range."""
    arrivals, services = _queues(pairs)
    speeds = jnp.array([2.0, 1.0, 0.5])
    starts, finishes, svc, slots = _kw(arrivals, services, 3, speeds=speeds)
    sl = np.asarray(slots)
    assert sl.min() >= 0 and sl.max() < 3
    expected = np.asarray(services) / np.asarray(speeds)[sl]
    np.testing.assert_allclose(np.asarray(svc), expected, rtol=1e-5)


# ------------------------------------------------ monotonicity properties


@given(pairs=_paths, c=st.integers(min_value=1, max_value=4))
@settings(max_examples=60, deadline=None)
def test_waits_monotone_nonincreasing_in_c(pairs, c):
    """Adding a (homogeneous) server never lengthens any job's wait on the
    same sample path — the classical KW coupling argument."""
    arrivals, services = _queues(pairs)
    s_lo, f_lo, _, _ = _kw(arrivals, services, c=c)
    s_hi, _, _, _ = _kw(arrivals, services, c=c + 1)
    w_lo = np.asarray(s_lo) - np.asarray(arrivals)
    w_hi = np.asarray(s_hi) - np.asarray(arrivals)
    assert np.all(w_hi <= w_lo + _tol(f_lo))


@given(pairs=_paths, c=st.integers(min_value=1, max_value=4),
       scale=st.floats(min_value=1.01, max_value=10.0))
@settings(max_examples=60, deadline=None)
def test_waits_monotone_nondecreasing_in_lambda(pairs, c, scale):
    """Scaling the arrival rate up (inter-arrivals down) on the same service
    draws never shortens any wait: Lindley/KW are monotone in each I_j."""
    arrivals, services = _queues(pairs)
    fast = arrivals / scale
    s_lo, f_lo, _, _ = _kw(arrivals, services, c=c)
    s_hi, _, _, _ = _kw(fast, services, c=c)
    w_lo = np.asarray(s_lo) - np.asarray(arrivals)
    w_hi = np.asarray(s_hi) - np.asarray(fast)
    assert np.all(w_hi >= w_lo - _tol(f_lo))


def test_waits_monotone_fixed_burst():
    """Fixed heavy burst: waits drop as c grows, until c covers the burst."""
    arrivals = jnp.array([0.1, 0.2, 0.3, 0.4])
    services = jnp.array([10.0, 10.0, 10.0, 10.0])
    waits = []
    for c in (1, 2, 4):
        starts, _, _, _ = _kw(arrivals, services, c=c)
        waits.append(float(jnp.sum(starts - arrivals)))
    assert waits[0] > waits[1] > waits[2]
    assert waits[2] == pytest.approx(0.0, abs=1e-6)


# -------------------------------------------- FIFO permutation invariance


@given(pairs=st.lists(st.tuples(_floats, _floats), min_size=2, max_size=30),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_tied_arrival_permutation_invariance_c1(pairs, seed):
    """c = 1 is work-conserving, so the workload process — and hence the
    clearing time of the final busy period (the last finish) — depends on
    simultaneous arrivals only through their TOTAL work: permuting which
    tied job carries which service time must not move the last finish.
    (Individual sojourns do move, and c > 1 genuinely breaks this — a big
    job pinned to one server changes the makespan — so FIFO implies the
    invariance exactly here and the test claims no more.)"""
    rng = np.random.default_rng(seed)
    inter = np.array([p[0] for p in pairs])
    services = np.array([p[1] for p in pairs])
    # quantize to force genuine arrival ties (several jobs per instant)
    arrivals = np.floor(np.cumsum(inter) / 25.0) * 25.0
    perm = rng.permutation(len(pairs))
    order = np.argsort(arrivals[perm], kind="stable")
    a2, s2 = arrivals[perm][order], services[perm][order]
    assert np.array_equal(a2, arrivals)  # same instants, services reshuffled
    _, f1 = vector.lindley(jnp.asarray(arrivals), jnp.asarray(services))
    _, f2 = vector.lindley(jnp.asarray(a2), jnp.asarray(s2))
    last1, last2 = float(jnp.max(f1)), float(jnp.max(f2))
    assert last1 == pytest.approx(last2, rel=1e-4)
    # and the KW scan at c=1 sees the identical clearing time
    _, f3, _, _ = _kw(jnp.asarray(a2), jnp.asarray(s2), c=1)
    assert float(jnp.max(f3)) == pytest.approx(last1, rel=1e-4)


@given(services=st.lists(_floats, min_size=1, max_size=30),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_batch_busy_period_service_permutation_invariant(services, seed):
    """c = 1, simultaneous arrivals: the server drains total work ΣS no
    matter the FIFO order, so the LAST finish is service-permutation
    invariant (individual sojourns of course are not)."""
    rng = np.random.default_rng(seed)
    s = np.array(services)
    arrivals = jnp.full((len(s),), 1.0)
    _, f1 = vector.lindley(arrivals, jnp.asarray(s))
    _, f2 = vector.lindley(arrivals, jnp.asarray(rng.permutation(s)))
    # f32 cumsum reassociation: a few ulps of the total drained work
    assert float(f1[-1]) == pytest.approx(float(f2[-1]), rel=1e-4)


# ------------------------------------------- Pallas kernel path parity


@given(pairs=_paths, c=st.integers(min_value=1, max_value=4))
@settings(max_examples=20, deadline=None)
def test_kernel_queue_matches_scan(pairs, c):
    """The Pallas kw_queue kernel (interpret mode) reproduces the lax.scan
    recursion path by path — so every monotonicity/sanity property proved
    above transfers to the kernel path verbatim."""
    from repro.kernels.kw_queue import kw_queue as kw_kernel

    arrivals, services = _queues(pairs)
    speeds = jnp.ones((c,))
    outs_scan = _kw(arrivals, services, c)
    outs_kernel = kw_kernel(arrivals[None, :], services[None, :], speeds)
    for a, b in zip(outs_kernel[:3], outs_scan[:3]):
        np.testing.assert_allclose(
            np.asarray(a[0]), np.asarray(b), rtol=1e-5, atol=_tol(outs_scan[1])
        )
    assert np.array_equal(np.asarray(outs_kernel[3][0]), np.asarray(outs_scan[3]))


def test_kernel_waits_monotone_fixed_burst():
    """The fixed-burst monotonicity story holds on the kernel path too."""
    from repro.kernels.kw_queue import kw_queue as kw_kernel

    arrivals = jnp.array([[0.1, 0.2, 0.3, 0.4]])
    services = jnp.array([[10.0, 10.0, 10.0, 10.0]])
    waits = []
    for c in (1, 2, 4):
        starts, _, _, _ = kw_kernel(arrivals, services, jnp.ones((c,)))
        waits.append(float(jnp.sum(starts - arrivals)))
    assert waits[0] > waits[1] > waits[2]
    assert waits[2] == pytest.approx(0.0, abs=1e-6)


def test_fleet_rollout_kernel_path_matches_scan_path():
    """`fleet_rollout(kernel=True)` is bit-for-bit the scan path (same key,
    same draws, identical queue recursion) for homogeneous and mixed
    fleets."""
    from repro.core import ShiftedExp, SingleForkPolicy
    from repro.fleet import MachineClass

    dist, pol = ShiftedExp(1.0, 1.0), SingleForkPolicy(0.2, 1, True)
    import jax

    for kwargs in (dict(c=3), dict(classes=(MachineClass("fast", 16, 1.0),
                                            MachineClass("slow", 16, 0.5)))):
        key = jax.random.PRNGKey(4)
        a = vector.fleet_rollout(dist, pol, 0.4, 8, 80, m_trials=6, key=key, **kwargs)
        b = vector.fleet_rollout(
            dist, pol, 0.4, 8, 80, m_trials=6, key=key, kernel=True, **kwargs
        )
        np.testing.assert_allclose(
            np.asarray(a.sojourn), np.asarray(b.sojourn), rtol=1e-6, atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(a.cost), np.asarray(b.cost), rtol=1e-6, atol=1e-6
        )
        assert np.array_equal(np.asarray(a.slot), np.asarray(b.slot))


# ---------------------------------------- rollout-level glue invariants


def test_fleet_rollout_c_dispatch_and_validation():
    from repro.core import ShiftedExp, SingleForkPolicy

    dist, pol = ShiftedExp(1.0, 1.0), SingleForkPolicy(0.2, 1, True)
    r1 = vector.fleet_rollout(dist, pol, 0.1, 8, 50, m_trials=4)
    assert r1.slot is None  # closed-form Lindley path
    r2 = vector.fleet_rollout(dist, pol, 0.1, 8, 50, m_trials=4, c=3)
    assert r2.slot is not None and int(jnp.max(r2.slot)) <= 2
    with pytest.raises(ValueError):
        vector.fleet_rollout(dist, pol, 0.1, 8, 50, m_trials=4, c=0)
    from repro.fleet import MachineClass

    with pytest.raises(ValueError, match="multiple"):
        vector.fleet_rollout(
            dist, pol, 0.1, 8, 50, m_trials=4, classes=(MachineClass("x", 12),)
        )
    with pytest.raises(ValueError, match="disagrees"):
        vector.fleet_rollout(
            dist, pol, 0.1, 8, 50, m_trials=4, c=3, classes=(MachineClass("x", 16),)
        )


def test_fleet_rollout_more_slots_never_hurts():
    """Same seed, growing c: mean wait is non-increasing, and with classes
    sorted fastest-first the fastest class takes the largest job share."""
    from repro.core import ShiftedExp, SingleForkPolicy
    from repro.fleet import MachineClass

    dist, pol = ShiftedExp(1.0, 1.0), SingleForkPolicy(0.2, 1, True)
    waits = [
        vector.fleet_rollout(dist, pol, 0.4, 8, 200, m_trials=16, c=c).mean_wait
        for c in (1, 2, 4)
    ]
    assert waits[0] >= waits[1] >= waits[2]
    classes = (MachineClass("fast", 16, 1.0), MachineClass("slow", 16, 0.25))
    res = vector.fleet_rollout(dist, pol, 0.4, 8, 200, m_trials=16, classes=classes)
    share_fast = float(jnp.mean(res.slot < 2))  # fast contributes slots 0-1
    assert share_fast > 0.5
