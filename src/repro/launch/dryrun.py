import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell
against ShapeDtypeStruct inputs, record memory/cost analysis + collective
bytes parsed from the optimized HLO.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh multi

Results are cached incrementally under benchmarks/results/dryrun/ so reruns
skip completed cells (--force recomputes).
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, applicability
from repro.launch.steps import plan_decode, plan_prefill, plan_train

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

_COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b"
)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in (optimized) HLO text.

    HLO lines look like:
      %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %p), dims=...
    We count the *operand* sizes (the data each chip injects into the
    network), falling back to the result size when operands aren't typed.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if m is None or "= " not in line:
            continue
        op = m.group(1)
        if f" {op}(" not in line and f"{op}-start(" not in line and f"{op}(" not in line:
            continue
        # operands: typed shapes inside the call parens
        call = line.split(op, 1)[1]
        shapes = _SHAPE_RE.findall(call)
        if shapes:
            nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        else:  # fall back to the result shape (before the '=')
            res = _SHAPE_RE.findall(line.split("=", 1)[1])
            nbytes = _shape_bytes(*res[0]) if res else 0
        out[op] = out.get(op, 0) + nbytes
    return out


#: ops that alias/bookkeep rather than touch HBM on TPU (while-loop state
#: threading, tuple plumbing, layout bitcasts).  XLA:CPU's cost analysis
#: charges them bytes; a TPU execution would not.  The roofline memory term
#: uses bytes excluding these (raw kept alongside).
_ALIAS_OPS = ("get-tuple-element", "parameter", "bitcast", "tuple", "copy")

_HLO_OP_RE = re.compile(r"=\s+([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+([a-z0-9-]+)")


def bytes_by_op(hlo_text: str) -> dict:
    """Result-shape bytes aggregated by op kind over the per-device HLO."""
    agg: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _HLO_OP_RE.search(line)
        if not m:
            continue
        dtype, dims, op = m.groups()
        agg[op] = agg.get(op, 0.0) + _shape_bytes(dtype, dims)
    return agg


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
        "peak_memory_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    keep = ("flops", "transcendentals", "bytes accessed", "optimal_seconds")
    return {k: float(v) for k, v in ca.items() if k in keep}


def run_cell(arch: str, shape_name: str, mesh_kind: str, remat: str = "none",
             serve_rules: str = "train", moe_impl: str | None = None,
             mla_decode_impl: str | None = None, pin_cache: bool = False,
             capacity_factor: float | None = None, ssm_chunk: int | None = None,
             tag: str = "") -> dict:
    import dataclasses as _dc

    cfg = get_config(arch)
    if moe_impl:
        cfg = cfg.replace(moe_impl=moe_impl)
    if mla_decode_impl:
        cfg = cfg.replace(mla_decode_impl=mla_decode_impl)
    if capacity_factor is not None and cfg.moe is not None:
        cfg = cfg.replace(moe=_dc.replace(cfg.moe, capacity_factor=capacity_factor))
    if ssm_chunk is not None and cfg.ssm is not None:
        cfg = cfg.replace(ssm=_dc.replace(cfg.ssm, chunk=ssm_chunk))
    shape = SHAPES[shape_name]
    ok, reason = applicability(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "remat": remat, "serve_rules": serve_rules,
    }
    if not ok:
        rec.update(status="SKIP", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = None
    if shape.kind != "train" and serve_rules == "stationary":
        rules = shd.rules_serve_stationary(mesh)

    def lower_compile(cfg_v):
        t0 = time.time()
        if shape.kind == "train":
            fn, in_sh, out_sh, inputs = plan_train(cfg_v, shape, mesh, remat=remat)
        elif shape.kind == "prefill":
            fn, in_sh, out_sh, inputs = plan_prefill(cfg_v, shape, mesh, rules=rules)
        else:
            fn, in_sh, out_sh, inputs = plan_decode(
                cfg_v, shape, mesh, rules=rules, pin_cache=pin_cache
            )
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*inputs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        return compiled, t_lower, time.time() - t0

    compiled, t_lower, t_compile = lower_compile(cfg)
    text1 = compiled.as_text()
    cost1 = _cost_analysis_dict(compiled)
    coll1 = collective_bytes(text1)
    ops1 = bytes_by_op(text1)

    # --- loop-body cost correction -------------------------------------
    # XLA's HloCostAnalysis counts a while-loop body ONCE regardless of the
    # trip count, so everything inside the layer scan is undercounted.
    # Re-lowering with scan unroll=2 duplicates each scan body exactly once;
    # the delta is the summed per-layer body cost across scan sites, and
    #   corrected = A1 + (A2 - A1) * (total_layers - n_sites) / n_sites
    # (valid because each arch's scan bodies have equal per-layer cost; see
    # ModelConfig.scan_sites).
    n_sites, total_layers = cfg.scan_sites(shape.kind)
    compiled2, _, t_compile2 = lower_compile(cfg.replace(scan_unroll=2))
    text2 = compiled2.as_text()
    cost2 = _cost_analysis_dict(compiled2)
    coll2 = collective_bytes(text2)
    ops2 = bytes_by_op(text2)
    factor = (total_layers - n_sites) / n_sites

    def correct(a1: dict, a2: dict) -> dict:
        keys = set(a1) | set(a2)
        return {
            k: a1.get(k, 0.0) + (a2.get(k, 0.0) - a1.get(k, 0.0)) * factor
            for k in keys
        }

    ops_corrected = correct(ops1, ops2)
    adjusted = sum(v for k, v in ops_corrected.items() if k not in _ALIAS_OPS)
    rec.update(
        status="OK",
        n_devices=mesh.devices.size,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile + t_compile2, 2),
        memory=_memory_analysis_dict(compiled),
        cost_raw=cost1,
        cost=correct(cost1, cost2),
        collectives_raw=coll1,
        collectives={k: int(v) for k, v in correct(coll1, coll2).items()},
        bytes_by_op={k: int(v) for k, v in sorted(ops_corrected.items(), key=lambda kv: -kv[1])[:12]},
        bytes_adjusted=int(adjusted),
        scan_sites=[n_sites, total_layers],
    )
    return rec


def _cell_path(arch, shape, mesh_kind, tag="") -> Path:
    suffix = f"__{tag}" if tag else ""
    return RESULTS_DIR / f"{arch}__{shape}__{mesh_kind}{suffix}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--serve-rules", default="train", choices=["train", "stationary"])
    ap.add_argument("--moe-impl", default=None, choices=[None, "gather", "dense"])
    ap.add_argument("--mla-decode-impl", default=None, choices=[None, "naive", "absorbed"])
    ap.add_argument("--pin-decode-cache", action="store_true")
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--tag", default="", help="variant tag for §Perf iterations")
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_fail = n_cached = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = _cell_path(arch, shape, mesh_kind, args.tag)
                if path.exists() and not args.force:
                    prev = json.loads(path.read_text())
                    if prev.get("status") in ("OK", "SKIP"):
                        n_cached += 1
                        continue
                try:
                    rec = run_cell(
                        arch, shape, mesh_kind, remat=args.remat,
                        serve_rules=args.serve_rules, moe_impl=args.moe_impl,
                        mla_decode_impl=args.mla_decode_impl,
                        pin_cache=args.pin_decode_cache,
                        capacity_factor=args.capacity_factor,
                        ssm_chunk=args.ssm_chunk, tag=args.tag,
                    )
                except Exception as e:  # a failure here is a sharding bug
                    rec = {
                        "arch": arch, "shape": shape, "mesh": mesh_kind,
                        "tag": args.tag, "status": "FAIL",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                path.write_text(json.dumps(rec, indent=1))
                st = rec["status"]
                n_ok += st == "OK"
                n_skip += st == "SKIP"
                n_fail += st == "FAIL"
                extra = ""
                if st == "OK":
                    fl = rec["cost"].get("flops", 0)
                    extra = f"flops={fl:.3e} compile={rec['compile_s']}s"
                elif st == "FAIL":
                    extra = rec["error"][:140]
                print(f"[{st}] {arch} x {shape} x {mesh_kind} {extra}", flush=True)
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail} cached={n_cached}")


if __name__ == "__main__":
    main()
