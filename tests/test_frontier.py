"""The fused frontier engine vs the per-cell loop it replaced.

`vector.frontier` evaluates a whole (λ × π) grid as ONE device program over
shared common-random-number draws; `vector.sweep_loop` dispatches one
`fleet_rollout` per cell.  The two draw independently, so they must agree
within Monte-Carlo error on every shared cell — and the engine's own knobs
(cell padding, r_cap pinning, the Pallas kw_queue switch, the `sweep`
wrapper, `policy_search` reuse) must not change results at all.
"""

import jax
import numpy as np
import pytest

from repro.core import Empirical, ShiftedExp, SingleForkPolicy
from repro.fleet import MachineClass, vector

DIST = ShiftedExp(1.0, 1.0)
POLICIES = (
    SingleForkPolicy(0.0, 0, True),
    SingleForkPolicy(0.1, 1, True),
    SingleForkPolicy(0.2, 1, False),
)
LAMS = (0.08, 0.16)
N, N_JOBS, M_TRIALS = 8, 200, 24


def _cells(rows):
    return {(r["policy"], r["lam"]): r for r in rows}


def test_frontier_matches_per_cell_loop_within_mc_error():
    fused = vector.frontier(
        DIST, POLICIES, LAMS, N, N_JOBS, m_trials=M_TRIALS, key=jax.random.PRNGKey(1)
    )
    loop = vector.sweep_loop(
        DIST, POLICIES, LAMS, N, N_JOBS, m_trials=M_TRIALS, key=jax.random.PRNGKey(2)
    )
    assert len(fused) == len(POLICIES) * len(LAMS)
    lf, ll = _cells(fused), _cells(loop)
    assert lf.keys() == ll.keys()
    for cell in lf:
        f, l = lf[cell], ll[cell]
        sigma = max(float(np.hypot(f["sojourn_std_err"], l["sojourn_std_err"])), 1e-12)
        assert abs(f["mean_sojourn"] - l["mean_sojourn"]) / sigma < 5.0, cell
        assert f["mean_cost"] == pytest.approx(l["mean_cost"], abs=0.1)
        # the loop's summary() keys are all present (sweep drop-in format)
        for key in ("mean_wait", "mean_service", "utilization", "p50", "p99",
                    "p999", "sojourn_std_err"):
            assert key in f


def test_frontier_kw_grid_matches_loop():
    """c > 1 (KW scan) and heterogeneous classes agree with the loop too."""
    mix = (MachineClass("fast", 2 * N, 1.0), MachineClass("slow", 2 * N, 0.5))
    for kwargs in (dict(c=3), dict(classes=mix)):
        fused = vector.frontier(
            DIST, POLICIES[:2], (0.4,), N, N_JOBS, m_trials=M_TRIALS,
            key=jax.random.PRNGKey(3), **kwargs,
        )
        loop = vector.sweep_loop(
            DIST, POLICIES[:2], (0.4,), N, N_JOBS, m_trials=M_TRIALS,
            key=jax.random.PRNGKey(4), **kwargs,
        )
        for f, l in zip(fused, loop):
            sigma = max(float(np.hypot(f["sojourn_std_err"], l["sojourn_std_err"])), 1e-12)
            assert abs(f["mean_sojourn"] - l["mean_sojourn"]) / sigma < 5.0
    # per-class utilization keys mirror VectorFleetResult.summary()
    assert "util_fast" in fused[0] and "util_slow" in fused[0]


def test_frontier_padding_does_not_change_results():
    """Bucket padding adds inert duplicate cells dropped on return —
    real-cell stats must be identical."""
    key = jax.random.PRNGKey(5)
    base = vector.frontier(
        DIST, POLICIES, LAMS, N, 100, m_trials=8, key=key, pad_cells=False
    )
    padded = vector.frontier(
        DIST, POLICIES, LAMS, N, 100, m_trials=8, key=key, pad_cells=True
    )
    for a, b in zip(base, padded):
        assert a["mean_sojourn"] == pytest.approx(b["mean_sojourn"], rel=1e-6)
        assert a["mean_cost"] == pytest.approx(b["mean_cost"], rel=1e-6)


def test_frontier_rcap_shifts_draws_within_mc_error():
    """Widening r_cap reshapes the fresh-draw tensor, so the draw stream —
    and hence the Monte-Carlo estimates — legitimately change; the masking
    guarantees the estimator stays unbiased, so results for the same grid
    must agree within MC error (NOT bit-for-bit)."""
    key = jax.random.PRNGKey(12)
    m_trials = 24
    tight = vector.frontier(DIST, POLICIES, LAMS, N, 200, m_trials=m_trials, key=key)
    wide = vector.frontier(
        DIST, POLICIES, LAMS, N, 200, m_trials=m_trials, key=key, r_cap=4
    )
    for a, b in zip(tight, wide):
        sigma = max(float(np.hypot(a["sojourn_std_err"], b["sojourn_std_err"])), 1e-12)
        assert abs(a["mean_sojourn"] - b["mean_sojourn"]) / sigma < 5.0


def test_frontier_kernel_switch_is_exact():
    """kernel=True routes the queue through the Pallas kw_queue kernel on
    identical draws: results match the scan path to float tolerance."""
    key = jax.random.PRNGKey(6)
    scan = vector.frontier(DIST, POLICIES, (0.4,), N, 120, m_trials=8, c=2, key=key)
    kern = vector.frontier(
        DIST, POLICIES, (0.4,), N, 120, m_trials=8, c=2, key=key, kernel=True
    )
    for a, b in zip(scan, kern):
        assert a["mean_sojourn"] == pytest.approx(b["mean_sojourn"], rel=1e-5)
        assert a["p99"] == pytest.approx(b["p99"], rel=1e-5)


def test_sweep_is_a_frontier_wrapper():
    key = jax.random.PRNGKey(7)
    s = vector.sweep(DIST, POLICIES, LAMS, N, 100, m_trials=8, key=key)
    f = vector.frontier(DIST, POLICIES, LAMS, N, 100, m_trials=8, key=key)
    assert [r["mean_sojourn"] for r in s] == [r["mean_sojourn"] for r in f]


def test_frontier_empirical_paths_agree():
    """Raw samples and Empirical(samples) drive the identical traced path."""
    x = np.random.default_rng(0).exponential(1.0, 400) + 1.0
    key = jax.random.PRNGKey(8)
    a = vector.frontier(x, POLICIES, (0.3,), N, 100, m_trials=8, key=key)
    b = vector.frontier(Empirical(x), POLICIES, (0.3,), N, 100, m_trials=8, key=key)
    for ra, rb in zip(a, b):
        assert ra["mean_sojourn"] == pytest.approx(rb["mean_sojourn"], rel=1e-6)


def test_policy_search_is_the_frontier_engine_at_one_lambda():
    x = np.random.default_rng(1).exponential(1.0, 400) + 1.0
    key = jax.random.PRNGKey(9)
    search = vector.policy_search(
        x, POLICIES, lam=0.3, n=N, n_jobs=100, m_trials=8, key=key
    )
    front = vector.frontier(x, POLICIES, (0.3,), N, 100, m_trials=8, key=key)
    for s, f in zip(search, front):
        assert s["mean_sojourn"] == pytest.approx(f["mean_sojourn"], rel=1e-6)
        assert s["rho"] == pytest.approx(f["rho"], rel=1e-6)
        assert s["policy"] in POLICIES  # search rows carry the policy object


def test_masked_single_fork_matches_static_sampler():
    """Dynamic-fork-point semantics ≡ `single_fork_batch` on shared draws
    (the quantile-transform route, analytic distribution)."""
    import jax.numpy as jnp

    n, s, r = 10, 3, 2
    key = jax.random.PRNGKey(10)
    for keep in (True, False):
        # reproduce single_fork_batch's draw structure through the shared
        # quantile transform so the comparison is exact, not statistical
        kx, ky = jax.random.split(key)
        x_sorted = jnp.sort(DIST.sample(kx, (64, n)), axis=-1)
        fresh_static = DIST.sample(ky, (64, s, r + 1))
        # masked path consumes an (n, r_cap) fresh block; place the static
        # draws in the straggler rows (iota >= k) it actually reads
        fresh = jnp.zeros((64, n, r + 1))
        fresh = fresh.at[:, n - s :, :].set(fresh_static)
        T_dyn, C_dyn = vector.masked_single_fork(
            x_sorted, fresh, jnp.int32(n - s), jnp.int32(r), keep
        )

        def ref_batch(x_sorted, fresh_static):
            k = n - s
            t1 = x_sorted[..., k - 1]
            c1 = jnp.sum(jnp.where(jnp.arange(n) < k, x_sorted, 0.0), axis=-1) + s * t1
            stragglers = x_sorted[..., k:]
            if keep:
                y = jnp.minimum(
                    stragglers - t1[..., None], jnp.min(fresh_static[..., :r], axis=-1)
                )
            else:
                y = jnp.min(fresh_static, axis=-1)
            return t1 + jnp.max(y, axis=-1), (c1 + (r + 1) * jnp.sum(y, axis=-1)) / n

        T_ref, C_ref = ref_batch(x_sorted, fresh_static)
        np.testing.assert_allclose(np.asarray(T_dyn), np.asarray(T_ref), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(C_dyn), np.asarray(C_ref), rtol=1e-6)


def test_frontier_validations():
    with pytest.raises(ValueError, match="lam"):
        vector.frontier(DIST, POLICIES, (0.0,), N, 50, m_trials=2)
    with pytest.raises(ValueError, match="candidate"):
        vector.frontier(DIST, [], (0.1,), N, 50, m_trials=2)
    with pytest.raises(ValueError, match="arrival rate"):
        vector.frontier(DIST, POLICIES, (), N, 50, m_trials=2)
    with pytest.raises(ValueError, match="r_cap"):
        vector.frontier(
            DIST, (SingleForkPolicy(0.1, 3, True),), (0.1,), N, 50, m_trials=2, r_cap=2
        )
    with pytest.raises(ValueError, match="2 samples"):
        vector.frontier(np.ones(1), POLICIES, (0.1,), N, 50, m_trials=2)


def test_slot_arrays_cache_hits():
    """(n, c, classes) geometry resolution is cached across re-plans."""
    vector._slot_arrays_cached.cache_clear()
    mix = (MachineClass("a", 16, 1.0), MachineClass("b", 16, 0.5))
    for _ in range(5):
        vector._slot_arrays(8, None, mix)
        vector._slot_arrays(8, 3, None)
    info = vector._slot_arrays_cached.cache_info()
    assert info.misses == 2 and info.hits == 8
    # cached arrays are the same objects — no per-call rebuilds
    a = vector._slot_arrays(8, 3, None)
    b = vector._slot_arrays(8, 3, None)
    assert a[0] is b[0]
