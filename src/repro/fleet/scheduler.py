"""Capacity-aware fleet scheduler: the discrete-event heart of repro.fleet.

Semantics (DESIGN.md §9):

  * the fleet has `capacity` identical worker slots; every running task
    copy occupies one slot from launch until first-finisher cancellation;
  * jobs queue for admission — a job starts only when `n_tasks` slots are
    free (gang scheduling: a parallel job cannot run partially).  FIFO is
    strict head-of-line; "priority" picks the lowest `priority` value among
    queued jobs but still blocks behind an unfittable head only if nothing
    fits (backfilling smaller/urgent jobs is exactly what the knob is for);
  * replication follows the job's single-/multi-fork policy via the same
    `num_stragglers` fork-point rule as the single-job executor: when
    (1-p)n of a job's tasks are done, each straggler gets r fresh copies
    (keep) or is killed and relaunched with r+1 copies.  Replicas are
    launched *best effort* — only as many as free slots allow (a kill
    always nets at least one fresh copy: the cancel frees a slot first);
  * `relaunch_delay` postpones the fork by a fixed delay after the trigger
    ("delayed relaunch", Aktaş-Peng-Soljanin 2017): copies keep running
    during the delay and the kill, if any, happens at the delayed instant;
  * `preempt_replicas=True` lets admission cancel *speculative* copies
    (never the last live copy of a task) newest-first to free slots for a
    queued job's originals — replication yields to throughput when tight;
  * cost follows Definition 2: every copy is billed wall-clock from launch
    to first-finisher (or cancellation), summed per job and divided by n.

An optional `OnlinePolicyController` supplies the policy for jobs that
don't pin one, learning F̂_X from completed-task telemetry across jobs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.adaptive import OnlinePolicyController
from repro.core.policy import (
    BASELINE,
    MultiForkPolicy,
    SingleForkPolicy,
    num_stragglers,
)

from .events import Event, EventHeap
from .workload import Job

__all__ = ["FleetScheduler", "JobRecord"]


@dataclasses.dataclass
class JobRecord:
    """Per-job outcome; the unit the fleet metrics aggregate over."""

    job_id: int
    arrival: float
    start: float  # admission instant
    finish: float  # last task completion
    n_tasks: int
    cost: float  # Definition 2: sum of copy runtimes / n
    n_replicas: int  # fresh copies actually launched
    n_preempted: int  # copies cancelled by admission preemption
    policy: str

    @property
    def sojourn(self) -> float:
        return self.finish - self.arrival

    @property
    def wait(self) -> float:
        return self.start - self.arrival

    @property
    def service(self) -> float:
        return self.finish - self.start


@dataclasses.dataclass
class _Copy:
    start: float
    event: Event  # its copy_done event (cancel via heap)
    fresh: bool  # replica (vs original)
    live: bool = True


class _Task:
    __slots__ = ("done", "copies")

    def __init__(self):
        self.done = False
        self.copies: list[_Copy] = []

    @property
    def live_copies(self) -> list[_Copy]:
        return [c for c in self.copies if c.live]


class _RunningJob:
    def __init__(self, job: Job, t_start: float, stages, durations: np.ndarray):
        self.job = job
        self.t_start = t_start
        self.stages = stages  # ((p, r, keep), ...) remaining fork stages
        self.next_stage = 0
        self.durations = durations  # original-copy durations (telemetry)
        self.n_done = 0
        self.tasks = [_Task() for _ in range(job.n_tasks)]
        self.cost = 0.0
        self.n_replicas = 0
        self.n_preempted = 0
        self.fork_pending = False

    def stage_threshold(self) -> Optional[int]:
        """n_done count that triggers the next fork stage (None = no more)."""
        if self.next_stage >= len(self.stages):
            return None
        p, _, _ = self.stages[self.next_stage]
        return self.job.n_tasks - num_stragglers(self.job.n_tasks, p)


def _normalize_stages(policy) -> tuple:
    if policy is None:
        return ()
    if isinstance(policy, MultiForkPolicy):
        return tuple(policy.stages)
    if isinstance(policy, SingleForkPolicy):
        if policy.is_baseline:
            return ()
        return ((policy.p, policy.r, policy.keep),)
    raise TypeError(f"unsupported policy {policy!r}")


class FleetScheduler:
    def __init__(
        self,
        capacity: int,
        default_policy: SingleForkPolicy = BASELINE,
        discipline: str = "fifo",
        relaunch_delay: float = 0.0,
        preempt_replicas: bool = False,
        fork_overhead: float = 0.0,
        controller: Optional[OnlinePolicyController] = None,
        seed: int = 0,
    ):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if discipline not in ("fifo", "priority"):
            raise ValueError(f"unknown discipline {discipline!r}")
        self.capacity = capacity
        self.default_policy = default_policy
        self.discipline = discipline
        self.relaunch_delay = relaunch_delay
        self.preempt_replicas = preempt_replicas
        self.fork_overhead = fork_overhead
        self.controller = controller
        # decorrelated from workload generators that may share `seed`
        self.rng = np.random.default_rng((0x5C4ED, seed))
        # run state
        self.heap = EventHeap()
        self.queue: list[Job] = []
        self.running: dict[int, _RunningJob] = {}
        self.free = capacity
        self.records: list[JobRecord] = []
        # instrumentation (conservation + utilization)
        self.max_busy = 0
        self.busy_time = 0.0  # integral of busy slots over time (copy-seconds)
        self.now = 0.0

    # ------------------------------------------------------------------ run
    def run(self, jobs: Sequence[Job]) -> list[JobRecord]:
        """Simulate to completion of every job; returns per-job records."""
        ids = [j.job_id for j in jobs]
        if len(set(ids)) != len(ids):
            raise ValueError("job_ids must be unique (running state is keyed by id)")
        for job in jobs:
            self.heap.push(job.arrival, "arrive", job)
        while self.heap:
            ev = self.heap.pop()
            if ev is None:
                break
            assert ev.time >= self.now - 1e-9, "event time went backwards"
            self.now = ev.time
            if ev.kind == "arrive":
                self.queue.append(ev.data)
                self._try_admit()
            elif ev.kind == "copy_done":
                self._on_copy_done(ev)
                self._try_admit()
            elif ev.kind == "fork":
                self._on_fork(ev)
                self._try_admit()  # a kill stage can net-free slots
            else:  # pragma: no cover
                raise RuntimeError(f"unknown event kind {ev.kind}")
        if self.queue:  # every queued job must eventually fit
            stuck = [j.job_id for j in self.queue]
            raise RuntimeError(
                f"jobs {stuck} can never be admitted "
                f"(n_tasks > capacity={self.capacity}?)"
            )
        self.records.sort(key=lambda r: r.job_id)
        return self.records

    # ------------------------------------------------------------ admission
    def _next_queued(self) -> Optional[Job]:
        if not self.queue:
            return None
        if self.discipline == "fifo":
            return self.queue[0]
        # priority: most urgent first; FIFO among equals (arrival order is
        # list order since arrivals push in time order)
        return min(self.queue, key=lambda j: j.priority)

    def _try_admit(self) -> None:
        while True:
            job = self._next_queued()
            if job is None:
                return
            if job.n_tasks > self.capacity:
                raise RuntimeError(
                    f"job {job.job_id} needs {job.n_tasks} slots > capacity {self.capacity}"
                )
            if self.free < job.n_tasks and self.preempt_replicas:
                self._preempt_for(job.n_tasks - self.free)
            if self.free < job.n_tasks:
                if self.discipline == "priority":
                    # try the next-most-urgent job that fits (backfill)
                    fit = [j for j in self.queue if j.n_tasks <= self.free]
                    if fit:
                        job = min(fit, key=lambda j: j.priority)
                    else:
                        return
                else:
                    return  # FIFO head-of-line blocking
            self.queue.remove(job)
            self._start_job(job)

    def _preempt_for(self, needed: int) -> None:
        """Cancel speculative copies (never a task's last) newest-first —
        but only if that actually frees enough slots to admit; hedging is
        never sacrificed for an admission that still cannot happen."""
        victims: list[tuple[float, _RunningJob, _Copy]] = []
        for rjob in self.running.values():
            for task in rjob.tasks:
                if task.done:
                    continue
                live = task.live_copies
                # keep the oldest live copy; the rest are speculative
                for c in sorted(live, key=lambda c: c.start)[1:]:
                    victims.append((c.start, rjob, c))
        if len(victims) < needed:
            return
        victims.sort(key=lambda v: v[0], reverse=True)  # newest first
        for _, rjob, copy in victims[:needed]:
            self._cancel_copy(rjob, copy)
            rjob.n_preempted += 1

    def _start_job(self, job: Job) -> None:
        policy = job.policy
        if policy is None:
            policy = self.default_policy
            if self.controller is not None:
                # serve with the configured policy until the controller has
                # actually learned a replicating one (mirrors HedgedServer)
                learned = self.controller.current_policy()
                if not learned.is_baseline:
                    policy = learned
        stages = _normalize_stages(policy)
        n = job.n_tasks
        durations = np.asarray(job.dist.quantile(self.rng.random(n)), dtype=np.float64)
        rjob = _RunningJob(job, self.now, stages, durations)
        rjob.policy_label = policy.label() if hasattr(policy, "label") else "multifork"
        self.running[job.job_id] = rjob
        for i in range(n):
            self._launch_copy(rjob, i, float(durations[i]), fresh=False)
        # degenerate n=1 fork stages can trigger at 0 completions
        self._maybe_schedule_fork(rjob)

    # -------------------------------------------------------------- copies
    def _launch_copy(self, rjob: _RunningJob, task_id: int, duration: float, fresh: bool):
        assert self.free > 0, "launch with no free slot"
        self.free -= 1
        busy = self.capacity - self.free
        self.max_busy = max(self.max_busy, busy)
        ev = self.heap.push(self.now + duration, "copy_done", (rjob.job.job_id, task_id))
        copy = _Copy(start=self.now, event=ev, fresh=fresh)
        rjob.tasks[task_id].copies.append(copy)
        ev.data = (rjob.job.job_id, task_id, copy)
        if fresh:
            rjob.n_replicas += 1
        return copy

    def _cancel_copy(self, rjob: _RunningJob, copy: _Copy) -> None:
        """Stop a running copy now: bill its runtime, free its slot."""
        if not copy.live:
            return
        copy.live = False
        self.heap.cancel(copy.event)
        elapsed = self.now - copy.start
        rjob.cost += elapsed
        self.busy_time += elapsed
        self.free += 1

    def _on_copy_done(self, ev: Event) -> None:
        job_id, task_id, copy = ev.data
        rjob = self.running.get(job_id)
        if rjob is None or not copy.live:
            return
        task = rjob.tasks[task_id]
        assert not task.done, "finish event for a completed task survived"
        task.done = True
        # winner billed to now; siblings cancelled (their bill also to now)
        copy.live = False
        elapsed = self.now - copy.start
        rjob.cost += elapsed
        self.busy_time += elapsed
        self.free += 1
        for c in task.live_copies:
            self._cancel_copy(rjob, c)
        rjob.n_done += 1
        if self.controller is not None:
            # simulation knows the true original duration even when a
            # replica won (same telemetry the single-job executor reports)
            self.controller.record_task_time(float(rjob.durations[task_id]))
        self._maybe_schedule_fork(rjob)
        if rjob.n_done == rjob.job.n_tasks:
            self._finish_job(rjob)

    def _maybe_schedule_fork(self, rjob: _RunningJob) -> None:
        thr = rjob.stage_threshold()
        if thr is None or rjob.fork_pending or rjob.n_done < thr:
            return
        rjob.fork_pending = True
        self.heap.push(
            self.now + self.relaunch_delay, "fork", (rjob.job.job_id, rjob.next_stage)
        )

    def _on_fork(self, ev: Event) -> None:
        job_id, stage_idx = ev.data
        rjob = self.running.get(job_id)
        if rjob is None or stage_idx != rjob.next_stage:
            return  # job finished during the relaunch delay, or stale stage
        _, r, keep = rjob.stages[stage_idx]
        rjob.next_stage += 1
        rjob.fork_pending = False
        stragglers = [i for i, t in enumerate(rjob.tasks) if not t.done]
        want = r if keep else r + 1
        for i in stragglers:
            task = rjob.tasks[i]
            if not keep:
                for c in task.live_copies:
                    self._cancel_copy(rjob, c)
            n_fresh = min(want, self.free)
            if n_fresh:
                fresh = np.asarray(
                    rjob.job.dist.quantile(self.rng.random(n_fresh)), dtype=np.float64
                )
                for d in fresh:
                    self._launch_copy(rjob, i, float(d) + self.fork_overhead, fresh=True)
            if not task.live_copies:
                # killed with zero slots anywhere (can't happen: the kill
                # freed one) — guard so a task is never silently lost
                raise RuntimeError(f"task {i} of job {job_id} left with no copy")
        # a later stage may already be due (its threshold <= current n_done)
        self._maybe_schedule_fork(rjob)

    # --------------------------------------------------------------- finish
    def _finish_job(self, rjob: _RunningJob) -> None:
        job = rjob.job
        del self.running[job.job_id]
        self.records.append(
            JobRecord(
                job_id=job.job_id,
                arrival=job.arrival,
                start=rjob.t_start,
                finish=self.now,
                n_tasks=job.n_tasks,
                cost=rjob.cost / job.n_tasks,
                n_replicas=rjob.n_replicas,
                n_preempted=rjob.n_preempted,
                policy=getattr(rjob, "policy_label", "?"),
            )
        )
        if self.controller is not None:
            self.controller.record_job_complete()
